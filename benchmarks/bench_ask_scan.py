"""Lambda-reduction benchmark: the five engines side by side.

The paper's central claim is that ASK beats Dynamic Parallelism because it
pays a smaller per-launch overhead lambda. This suite makes the claim
measurable across the whole engine ladder:

  ex         one flat kernel, no subdivision         (1 dispatch, no OLT)
  dp         one dispatch per subdivision-tree node  (lambda paid per node)
  ask        one dispatch per level + host sync      (lambda paid per level)
  ask_fused  one dispatch, worst-case OLT buffers    (lambda paid once,
                                                      memory worst-case)
  ask_scan   one dispatch, bounded OLT ring          (lambda paid once,
                                                      memory ~expected)
  ask_tuned  ask_scan with autotuned kernel routing  (same dispatches,
                                                      tuned schedules)

  ask_pooled one dispatch for a WHOLE batch, one     (lambda paid once per
             cross-frame pooled worklist per level    batch, ring ~ summed
                                                      expected occupancy)

The ``tuned_tier`` suite additionally emits a machine-readable
``BENCH_6.json`` (dispatches / ring rows / wall times / tuned-vs-jnp
speedup per registry workload) and the ``pooled_tier`` suite a
``BENCH_7.json`` (pooled vs per-frame-planned ring rows on a
heterogeneous batch), and the ``tile_service`` suite a ``BENCH_9.json``
(content-addressed dwell-cache hit rate and dispatch savings on an
overlapping pan/zoom stream); CI's ``compare_bench`` gate diffs all
three against the checked-in baselines.

Rows (``name,case,value``):
  ask_scan_launches_<m>      kernel dispatch count
  ask_scan_olt_peak_rows_<m> peak live OLT rows resident at once
  ask_scan_olt_total_rows_<m> total OLT rows allocated across the program
  ask_scan_wall_ms_<m>       best-of-3 wall time (CPU/jnp backend)
  ask_scan_identical_<m>     canvas identical to run_ask (1/0)
plus ``ask_scan_batch_*`` rows for the vmapped multi-frame front-end.

Peak-rows accounting: ask re-uses one bucket per level (peak = largest
parent+child pair); fused keeps every per-level worst-case buffer inside
one program (peak = sum); scan keeps exactly two ring buffers (peak =
2 x max level capacity).
"""

from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
import textwrap
import time
from pathlib import Path

import numpy as np

from repro.core.ask import run_ask
from repro.mandelbrot import MandelbrotProblem, solve, solve_batch

DWELL = 128

METHODS = ("ex", "dp", "ask", "ask_fused", "ask_scan", "ask_tuned")


def _best_time(fn, reps=3):
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts)


def _peak_rows(method: str, stats, r: int) -> int:
    caps = list(getattr(stats, "olt_caps", ()) or ())
    if method == "ex" or not caps:
        return 0
    if method == "dp":
        return 1  # one 1-row OLT per dispatch
    if method == "ask":
        # serial kernels: parent bucket + the transient child write-OLT
        # (run_ask sizes it next_pow2(cap * r^2) before the next level's
        # bucket shrinks it back to next_pow2(count))
        from repro.core.olt import next_pow2
        if len(caps) == 1:
            return caps[0]
        return max(c + next_pow2(c * r * r) for c in caps[:-1])
    if method == "ask_fused":
        return sum(caps)  # all per-level buffers live in one program
    if method in ("ask_scan", "ask_tuned"):
        return 2 * max(caps)  # the double-buffered ring
    return sum(caps)


def engines(writer, n=256, g=4, r=2, B=16):
    prob = MandelbrotProblem(n=n, g=g, r=r, B=B, max_dwell=DWELL,
                             backend="jnp")
    reference, _ = run_ask(prob)
    reference = np.asarray(reference)
    case = f"n={n}"
    for method in METHODS:
        solve(prob, method)  # warm the jit caches
        canvas, stats = solve(prob, method)
        wall = _best_time(lambda m=method: solve(prob, m))
        launches = stats.kernel_launches if method != "ex" else 1
        writer(f"ask_scan_launches_{method}", case, launches)
        writer(f"ask_scan_olt_peak_rows_{method}", case,
               _peak_rows(method, stats, r) if method != "ex" else 0)
        writer(f"ask_scan_olt_total_rows_{method}", case,
               sum(getattr(stats, "olt_caps", ()) or ()) if method != "ex"
               else 0)
        writer(f"ask_scan_wall_ms_{method}", case, wall * 1e3)
        writer(f"ask_scan_identical_{method}", case,
               int(np.array_equal(np.asarray(canvas), reference)))


def batch_serving(writer, n=256, frames=8):
    """The serving front-end: F frames of a zoom sequence, one dispatch."""
    prob = MandelbrotProblem(n=n, g=4, r=2, B=16, max_dwell=DWELL,
                             backend="jnp")
    re0, im0, re1, im1 = prob.bounds
    zooms = np.linspace(0.0, 0.6, frames)
    bounds = [(re0 + z * (re1 - re0) * 0.4, im0 + z * (im1 - im0) * 0.4,
               re1 - z * (re1 - re0) * 0.4, im1 - z * (im1 - im0) * 0.4)
              for z in zooms]
    solve_batch(prob, bounds)  # warm
    t = _best_time(lambda: solve_batch(prob, bounds))
    _, stats = solve_batch(prob, bounds)
    writer("ask_scan_batch_frames", f"n={n}", frames)
    writer("ask_scan_batch_launches", f"n={n}", stats.kernel_launches)
    writer("ask_scan_batch_wall_ms", f"n={n}", t * 1e3)
    writer("ask_scan_batch_ms_per_frame", f"n={n}", t * 1e3 / frames)
    writer("ask_scan_batch_overflow", f"n={n}", stats.overflow_dropped)

    # single-frame loop as the serving baseline (same engine, F dispatches)
    def loop():
        for b in bounds:
            solve(dataclasses.replace(prob, bounds=tuple(b)), "ask_scan")

    loop()  # warm (each distinct bounds tuple retraces once)
    writer("ask_scan_unbatched_wall_ms", f"n={n}", _best_time(loop) * 1e3)


def sharded_serving(writer, n=128, frames=16, devices=8, chunk=8):
    """The sharded row: 1-device vs N-host-device frame-axis sharding.

    XLA locks the host device count at first init, so the comparison runs
    in a subprocess with ``--xla_force_host_platform_device_count``. Both
    mesh sizes stream the SAME chunked zoom trajectory through
    ``launch.render_service``; rows record wall time per mesh, dispatches
    per chunk (the acceptance target: exactly 1), and whether the sharded
    canvases are bit-identical to the 1-device render.
    """
    root = Path(__file__).resolve().parent.parent
    code = textwrap.dedent(f"""
        import json, time
        import numpy as np
        from repro.launch.mesh import make_frames_mesh
        from repro.launch.render_service import RenderService, zoom_bounds
        from repro.mandelbrot import MandelbrotProblem

        prob = MandelbrotProblem(n={n}, g=4, r=2, B=16, max_dwell={DWELL},
                                 backend="jnp")
        out = {{}}
        canvases = {{}}
        for ndev in (1, {devices}):
            svc = RenderService(prob, mesh=make_frames_mesh(ndev),
                                chunk_frames={chunk}, safety_factor=1e9)
            for _ in svc.stream(zoom_bounds(svc.chunk_frames)):
                pass  # warm the jitted sharded pipeline
            best = None
            for _ in range(2):
                c, rs = svc.render(zoom_bounds({frames}))
                best = rs if best is None or rs.wall_s < best.wall_s else best
            canvases[ndev] = c
            out[f"wall_ms_{{ndev}}dev"] = best.wall_s * 1e3
            out[f"dispatches_per_chunk_{{ndev}}dev"] = best.dispatches_per_chunk
            out[f"program_traces_{{ndev}}dev"] = best.program_traces
            out["chunks"] = best.chunks
        out["identical"] = int(np.array_equal(canvases[1], canvases[{devices}]))
        print("RESULT " + json.dumps(out))
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(root / "src")
    case = f"n={n} f={frames}"  # no commas: rows stay 3-column CSV
    try:
        r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                           text=True, timeout=900, env=env, cwd=root)
    except subprocess.TimeoutExpired:
        writer("ask_scan_sharded_error", case, "timeout after 900s")
        return
    if r.returncode != 0:
        tail = " ".join(r.stderr.split())[-200:].replace(",", ";")
        writer("ask_scan_sharded_error", case, tail)
        return
    res = json.loads(r.stdout.rsplit("RESULT ", 1)[1])
    writer("ask_scan_sharded_frames", case, frames)
    writer("ask_scan_sharded_devices", case, devices)
    writer("ask_scan_sharded_wall_ms_1dev", case, res["wall_ms_1dev"])
    writer(f"ask_scan_sharded_wall_ms_{devices}dev", case,
           res[f"wall_ms_{devices}dev"])
    writer("ask_scan_sharded_dispatches_per_chunk", case,
           res[f"dispatches_per_chunk_{devices}dev"])
    writer("ask_scan_sharded_program_traces", case,
           res[f"program_traces_{devices}dev"])
    writer("ask_scan_sharded_identical", case, res["identical"])


def planner_batch(writer, n=512, dwell=256, n_sparse=8, n_dense=4):
    """Heterogeneous-zoom acceptance rows: the occupancy-aware capacity
    planner (core/planner.py) against uniform safety_factor=2.0 sizing on
    a batch mixing zoomed-out (sparse) and deep-zoom (dense) frames.

    Rows record, per sizing policy: total OLT-ring memory (rows and
    bytes), regions overflow-dropped, and warm wall time. The planner
    must report overflow_dropped == 0 (retrying internally if a bucket
    runs hot) with strictly less total ring memory than the uniform
    baseline -- which, sized for the P=0.7 average, both over-allocates
    the sparse majority AND drops regions on the dense frames.
    """
    from repro.core.ask import scan_capacities
    from repro.core.planner import plan_capacities

    prob = MandelbrotProblem(n=n, g=4, r=2, B=16, max_dwell=dwell,
                             backend="jnp")

    def window(cx, cy, w):
        return (cx - w / 2, cy - w / 2, cx + w / 2, cy + w / 2)

    widths = np.geomspace(16.0, 4.0, n_sparse)
    sparse = [window(-0.5, 0.0, float(w)) for w in widths]
    dense = [window(-0.7436447860, 0.1318252536, 3.0 / 2 ** k)
             for k in np.linspace(4, 12, n_dense)]
    bounds = sparse + dense
    F = len(bounds)
    case = f"n={n} f={F}"

    plan = plan_capacities(prob, bounds, num_buckets=4)
    # the warm call (compiles every bucket program) already yields the
    # canvases + report; only the timing reps re-execute
    planned_canv, rep = solve_batch(prob, bounds, plan=plan)
    t_plan = _best_time(lambda: solve_batch(prob, bounds, plan=plan), reps=2)

    _, st_uni = solve_batch(prob, bounds, safety_factor=2.0)  # warm
    t_uni = _best_time(lambda: solve_batch(prob, bounds, safety_factor=2.0),
                       reps=2)
    uni_caps = scan_capacities(n, 4, 2, 16, safety_factor=2.0)
    uni_rows = F * 2 * max(uni_caps)

    exact, _ = solve_batch(prob, bounds, safety_factor=1e9)

    writer("ask_scan_planner_frames", case, F)
    writer("ask_scan_planner_buckets", case, len(plan.buckets))
    writer("ask_scan_planner_dispatches", case, rep.dispatches)
    writer("ask_scan_planner_retries", case, rep.retries)
    writer("ask_scan_planner_overflow", case, rep.overflow_dropped)
    writer("ask_scan_planner_ring_rows", case, rep.ring_rows)
    writer("ask_scan_planner_ring_bytes", case, rep.ring_bytes)
    writer("ask_scan_planner_wall_ms", case, t_plan * 1e3)
    writer("ask_scan_uniform2x_overflow", case, st_uni.overflow_dropped)
    writer("ask_scan_uniform2x_ring_rows", case, uni_rows)
    writer("ask_scan_uniform2x_ring_bytes", case, uni_rows * 8)
    writer("ask_scan_uniform2x_wall_ms", case, t_uni * 1e3)
    writer("ask_scan_planner_ring_vs_uniform", case,
           rep.ring_rows / uni_rows if uni_rows else 0.0)
    writer("ask_scan_planner_identical", case,
           int(np.array_equal(planned_canv, np.asarray(exact))))


def pipelined_serving(writer, n=256, dwell=128, frames=64, chunk=8,
                      sink_ms=40.0):
    """Async-pipeline acceptance rows: RenderService pipeline_depth=2 vs
    the synchronous path on a >= 8-chunk trajectory with a blocking
    per-chunk host-I/O sink (a sleep: models encoding/writing a chunk to
    disk or network without competing for the CPU cores XLA computes
    on). The pipelined wall time must land measurably below the sync
    path's summed per-chunk (compute + host-copy) cost, rs.busy_s.

    Runs in a subprocess: the measurement needs a pristine XLA client
    (background async execution), which earlier in-process suites and
    their child processes can perturb on small CI hosts.
    """
    root = Path(__file__).resolve().parent.parent
    code = textwrap.dedent(f"""
        import json, time
        import numpy as np
        from repro.launch.mesh import make_frames_mesh
        from repro.launch.render_service import RenderService, zoom_bounds
        from repro.mandelbrot import MandelbrotProblem

        prob = MandelbrotProblem(n={n}, g=4, r=2, B=16, max_dwell={dwell},
                                 backend="jnp")
        mesh = make_frames_mesh(1)

        def sink(canvases, stats):
            time.sleep({sink_ms} / 1e3)

        out = {{}}
        canvases = {{}}
        for depth in (1, 2):
            svc = RenderService(prob, mesh=mesh, chunk_frames={chunk},
                                pipeline_depth=depth, safety_factor=2.0)
            for _ in svc.stream(zoom_bounds(svc.chunk_frames)):
                pass  # warm the chunk program
            best = None
            for _ in range(2):
                c, rs = svc.render(zoom_bounds({frames}), sink=sink)
                best = rs if best is None or rs.wall_s < best.wall_s else best
            canvases[depth] = c
            key = "sync" if depth == 1 else "pipelined"
            out[f"{{key}}_wall_ms"] = best.wall_s * 1e3
            out[f"{{key}}_busy_ms"] = best.busy_s * 1e3
            out[f"{{key}}_fetch_ms"] = best.fetch_s * 1e3
            out["chunks"] = best.chunks
        out["identical"] = int(np.array_equal(canvases[1], canvases[2]))
        print("RESULT " + json.dumps(out))
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = str(root / "src")
    case = f"n={n} f={frames} chunk={chunk}"
    try:
        r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                           text=True, timeout=900, env=env, cwd=root)
    except subprocess.TimeoutExpired:
        writer("render_pipeline_error", case, "timeout after 900s")
        return
    if r.returncode != 0:
        tail = " ".join(r.stderr.split())[-200:].replace(",", ";")
        writer("render_pipeline_error", case, tail)
        return
    res = json.loads(r.stdout.rsplit("RESULT ", 1)[1])
    writer("render_pipeline_chunks", case, res["chunks"])
    writer("render_pipeline_sink_ms", case, sink_ms)
    writer("render_sync_busy_ms", case, res["sync_busy_ms"])
    writer("render_sync_wall_ms", case, res["sync_wall_ms"])
    writer("render_sync_fetch_ms", case, res["sync_fetch_ms"])
    writer("render_pipelined_wall_ms", case, res["pipelined_wall_ms"])
    writer("render_pipelined_fetch_ms", case, res["pipelined_fetch_ms"])
    writer("render_overlap_saved_ms", case,
           res["sync_busy_ms"] - res["pipelined_wall_ms"])
    writer("render_pipelined_speedup", case,
           res["sync_busy_ms"] / res["pipelined_wall_ms"]
           if res["pipelined_wall_ms"] else 0.0)
    writer("render_pipelined_identical", case, res["identical"])


def feedback_serving(writer, n=256, dwell=64, frames=48, chunk=4,
                     zoom=1.02, width0=6.0, safety_factor=1.1):
    """Closed-loop occupancy feedback acceptance rows: the feedback-
    driven render service (``RenderService(feedback=True)``) against the
    prior-only baseline (same chunking/retry machinery, ``adapt=False``)
    on a boundary-skimming zoom -- a trajectory that hugs the seahorse-
    valley boundary while still zoomed OUT, where the real subdivision
    density runs hotter than the zoom-depth prior.

    Rows record, per policy: total OLT-ring rows allocated (retry
    dispatches included), regions overflow-dropped (both must be 0 --
    the in-service retry guarantees it), frame retries, and dispatches.
    The feedback plan must reach 0 drops with FEWER ring rows and FEWER
    retries than the prior plan, and its cold-start chunk 0 must
    reproduce the prior plan exactly (same quantized P, "prior" source).
    """
    from repro.core.planner import ROW_BYTES
    from repro.launch.mesh import make_frames_mesh
    from repro.launch.render_service import RenderService, zoom_bounds

    prob = MandelbrotProblem(n=n, g=4, r=2, B=16, max_dwell=dwell,
                             backend="jnp")
    mesh = make_frames_mesh(1)
    center = (-0.7436447860, 0.1318252536)  # seahorse valley

    def traj():
        return zoom_bounds(frames, center=center, width0=width0,
                           zoom_per_frame=zoom)

    case = f"n={n} f={frames} chunk={chunk}"
    ref, _ = RenderService(prob, mesh=mesh, chunk_frames=chunk,
                           safety_factor=1e9).render(traj())

    results = {}
    for adapt, key in ((False, "prior"), (True, "feedback")):
        svc = RenderService(prob, mesh=mesh, chunk_frames=chunk,
                            feedback=True, adapt=adapt,
                            safety_factor=safety_factor)
        canv, rs = svc.render(traj())
        results[key] = rs
        writer(f"ask_scan_{key}_ring_rows", case, rs.ring_rows)
        writer(f"ask_scan_{key}_ring_bytes", case, rs.ring_rows * ROW_BYTES)
        writer(f"ask_scan_{key}_overflow", case, rs.overflow_dropped)
        writer(f"ask_scan_{key}_retries", case, rs.retries)
        writer(f"ask_scan_{key}_dispatches", case, rs.dispatches)
        writer(f"ask_scan_{key}_chunks", case, rs.chunks)
        writer(f"ask_scan_{key}_plan_signatures", case, rs.plan_signatures)
        writer(f"ask_scan_{key}_wall_ms", case, rs.wall_s * 1e3)
        writer(f"ask_scan_{key}_identical", case,
               int(np.array_equal(canv, ref)))

    prior, fb = results["prior"], results["feedback"]
    writer("ask_scan_feedback_ring_vs_prior", case,
           fb.ring_rows / prior.ring_rows if prior.ring_rows else 0.0)
    writer("ask_scan_feedback_cold_start_matches_prior", case,
           int(fb.chunk_stats[0].p_subdiv == prior.chunk_stats[0].p_subdiv
               and fb.chunk_stats[0].p_source == "prior"))
    writer("ask_scan_feedback_measured_chunks", case,
           sum(1 for c in fb.chunk_stats if c.p_source == "measured"))


def workload_serving(writer, n=256, dwell=64, frames=24, chunk=4,
                     zoom=1.05, safety_factor=1.15):
    """Beyond-Mandelbrot scenario rows: the planned batch path and the
    prior/feedback serving loop on a julia zoom and a burning-ship zoom
    (each toward a boundary target of its own set), so the BENCH
    trajectories cover more than one workload.

    Per workload, rows record: the planned heterogeneous batch
    (buckets/dispatches/ring rows/0 drops, bit-identical to the exact
    batch) and the closed-loop serving comparison (prior-only vs
    feedback ring rows and retries -- both 0-drop, feedback planning
    from each workload's OWN measured occupancy). The priors come from
    the per-workload bands on the ``WorkloadSpec``, not the Mandelbrot
    constants.
    """
    from repro.core.planner import ROW_BYTES
    from repro.launch.mesh import make_frames_mesh
    from repro.launch.render_service import RenderService, zoom_bounds
    from repro.workloads import FrameProblem

    # (workload, zoom target on its boundary, starting width)
    targets = (("julia", (0.0, 0.0), 3.2),
               ("burning_ship", (-1.7548, -0.0281), 4.0))
    mesh = make_frames_mesh(1)
    for wl, center, width0 in targets:
        prob = FrameProblem(n=n, g=4, r=2, B=16, max_dwell=dwell,
                            backend="jnp", workload=wl)
        case = f"wl={wl} n={n} f={frames}"

        def traj():
            return zoom_bounds(frames, center=center, width0=width0,
                               zoom_per_frame=zoom)

        # planned batch: wide establishing shots + the deep tail, one
        # compiled program per capacity bucket, each frame's P from the
        # workload's own zoom-depth prior
        batch = list(zoom_bounds(8, center=center, width0=width0 * 8,
                                 zoom_per_frame=2.0))
        canv, rep = solve_batch(prob, batch, plan=3)
        exact, _ = solve_batch(prob, batch, safety_factor=1e9)
        writer("ask_scan_wl_planned_buckets", case, len(rep.plan.buckets))
        writer("ask_scan_wl_planned_dispatches", case, rep.dispatches)
        writer("ask_scan_wl_planned_overflow", case, rep.overflow_dropped)
        writer("ask_scan_wl_planned_ring_rows", case, rep.ring_rows)
        writer("ask_scan_wl_planned_identical", case,
               int(np.array_equal(canv, np.asarray(exact))))

        # closed-loop serving: prior-only baseline vs feedback
        ref, _ = RenderService(prob, mesh=mesh, chunk_frames=chunk,
                               safety_factor=1e9).render(traj())
        results = {}
        for adapt, key in ((False, "prior"), (True, "feedback")):
            svc = RenderService(prob, mesh=mesh, chunk_frames=chunk,
                                feedback=True, adapt=adapt,
                                safety_factor=safety_factor)
            canv, rs = svc.render(traj())
            results[key] = rs
            writer(f"ask_scan_wl_{key}_ring_rows", case, rs.ring_rows)
            writer(f"ask_scan_wl_{key}_ring_bytes", case,
                   rs.ring_rows * ROW_BYTES)
            writer(f"ask_scan_wl_{key}_overflow", case, rs.overflow_dropped)
            writer(f"ask_scan_wl_{key}_retries", case, rs.retries)
            writer(f"ask_scan_wl_{key}_dispatches", case, rs.dispatches)
            writer(f"ask_scan_wl_{key}_identical", case,
                   int(np.array_equal(canv, ref)))
        prior, fb = results["prior"], results["feedback"]
        writer("ask_scan_wl_feedback_ring_vs_prior", case,
               fb.ring_rows / prior.ring_rows if prior.ring_rows else 0.0)
        writer("ask_scan_wl_feedback_measured_chunks", case,
               sum(1 for c in fb.chunk_stats if c.p_source == "measured"))


def tuned_tier(writer, n=256, dwell=64, bench_json=None):
    """The autotuned rung vs the plain scan engine, per registry workload.

    For every registered workload (the four escape-time sets AND the
    generated ``ssd_synth`` field) renders the 256^2 default viewport with
    ``ask_scan`` (jnp routing) and ``ask_tuned`` (autotune heuristics /
    cache), asserting the tuned canvas is bit-identical, and records
    dispatch count, ring rows, best-of-3 wall times, and the tuned-vs-jnp
    speedup. With ``bench_json`` the same numbers are written as the
    machine-readable ``BENCH_6.json`` CI's ``compare_bench`` gate diffs.
    """
    from repro.workloads import FrameProblem, available, solve

    payload = {"version": 1,
               "config": {"n": n, "max_dwell": dwell, "g": 4, "r": 2,
                          "B": 16},
               "workloads": {}}
    for wl in available():
        prob = FrameProblem(n=n, g=4, r=2, B=16, max_dwell=dwell,
                            backend="jnp", workload=wl)
        case = f"wl={wl} n={n}"
        base, base_stats = solve(prob, "ask_scan", safety_factor=1e9)
        tuned, stats = solve(prob, "ask_tuned", safety_factor=1e9)
        wall_jnp = _best_time(lambda: solve(prob, "ask_scan",
                                            safety_factor=1e9))
        wall_tuned = _best_time(lambda: solve(prob, "ask_tuned",
                                              safety_factor=1e9))
        identical = int(np.array_equal(np.asarray(base), np.asarray(tuned)))
        speedup = wall_jnp / wall_tuned if wall_tuned > 0 else 0.0
        ring_rows = stats.ring_rows
        writer("ask_tuned_dispatches", case, stats.kernel_launches)
        writer("ask_tuned_ring_rows", case, ring_rows)
        writer("ask_tuned_wall_ms_jnp", case, wall_jnp * 1e3)
        writer("ask_tuned_wall_ms_tuned", case, wall_tuned * 1e3)
        writer("ask_tuned_speedup", case, speedup)
        writer("ask_tuned_identical", case, identical)
        payload["workloads"][wl] = {
            "dispatches": int(stats.kernel_launches),
            "ring_rows": int(ring_rows),
            "wall_ms_jnp": round(wall_jnp * 1e3, 3),
            "wall_ms_tuned": round(wall_tuned * 1e3, 3),
            "speedup": round(speedup, 4),
            "identical": identical,
        }
    if bench_json:
        with open(bench_json, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
            fh.write("\n")
    return payload


def pooled_tier(writer, n=512, dwell=128, n_sparse=12, n_dense=4,
                bench_json=None):
    """Cross-frame pooled worklists vs the per-frame capacity plan.

    The same heterogeneous zoom batch as ``planner_batch`` (a sparse
    zoomed-out majority plus a deep seahorse-valley tail), solved two
    ways: the bucketed per-frame plan (``plan=4``, each bucket's ring
    sized for its WORST member) and the ``ask_pooled`` engine (one
    compacted cross-frame worklist per level, the ring sized from the
    SUM of per-frame expected occupancies). Pooling must land strictly
    below the per-frame plan's total ring rows -- averaging over a
    heterogeneous batch beats per-bucket maxima -- in ONE dispatch with
    zero overflow-drops and a bit-identical canvas. With ``bench_json``
    the numbers are written as the machine-readable ``BENCH_7.json``
    that CI's ``compare_bench`` gate diffs (the pooled config is the
    SAME in smoke and full mode so the checked-in baseline's exact
    ring-row / dispatch budgets stay comparable).
    """
    from repro.workloads import EngineOptions

    prob = MandelbrotProblem(n=n, g=4, r=2, B=16, max_dwell=dwell,
                             backend="jnp")

    def window(cx, cy, w):
        return (cx - w / 2, cy - w / 2, cx + w / 2, cy + w / 2)

    widths = np.geomspace(16.0, 4.0, n_sparse)
    sparse = [window(-0.5, 0.0, float(w)) for w in widths]
    dense = [window(-0.7436447860, 0.1318252536, 3.0 / 2 ** k)
             for k in np.linspace(4, 12, n_dense)]
    bounds = sparse + dense
    F = len(bounds)
    case = f"n={n} f={F}"

    planned_canv, base_rep = solve_batch(prob, bounds, plan=4)  # warm
    t_plan = _best_time(lambda: solve_batch(prob, bounds, plan=4), reps=2)

    opts = EngineOptions(engine="ask_pooled", plan=True)
    pooled_canv, pool_rep = solve_batch(prob, bounds, options=opts)  # warm
    t_pool = _best_time(lambda: solve_batch(prob, bounds, options=opts),
                        reps=2)

    identical = int(np.array_equal(np.asarray(planned_canv),
                                   np.asarray(pooled_canv)))
    below = int(pool_rep.ring_rows < base_rep.ring_rows)
    speedup = t_plan / t_pool if t_pool > 0 else 0.0

    writer("ask_pooled_frames", case, F)
    writer("ask_pooled_dispatches", case, pool_rep.dispatches)
    writer("ask_pooled_overflow", case, pool_rep.overflow_dropped)
    writer("ask_pooled_ring_rows", case, pool_rep.ring_rows)
    writer("ask_pooled_planned_ring_rows", case, base_rep.ring_rows)
    writer("ask_pooled_ring_vs_planned", case,
           pool_rep.ring_rows / base_rep.ring_rows
           if base_rep.ring_rows else 0.0)
    writer("ask_pooled_below_planned", case, below)
    writer("ask_pooled_wall_ms_planned", case, t_plan * 1e3)
    writer("ask_pooled_wall_ms_pooled", case, t_pool * 1e3)
    writer("ask_pooled_speedup", case, speedup)
    writer("ask_pooled_identical", case, identical)

    payload = {"version": 1,
               "config": {"n": n, "max_dwell": dwell, "g": 4, "r": 2,
                          "B": 16, "n_sparse": n_sparse,
                          "n_dense": n_dense},
               "workloads": {"mixed_mandelbrot": {
                   "identical": identical,
                   "dispatches": int(pool_rep.dispatches),
                   "ring_rows": int(pool_rep.ring_rows),
                   "planned_ring_rows": int(base_rep.ring_rows),
                   "overflow": int(pool_rep.overflow_dropped),
                   "below_planned": below,
                   "wall_ms_planned": round(t_plan * 1e3, 3),
                   "wall_ms_pooled": round(t_pool * 1e3, 3),
                   "speedup": round(speedup, 4),
               }}}
    if bench_json:
        with open(bench_json, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
            fh.write("\n")
    return payload


def pooled_tuned_tier(writer, n=256, dwell=64, frames=6, bench_json=None):
    """The banded pooled Pallas tier (ISSUE 10): ask_pooled jnp vs tuned.

    Renders a zoom ladder of ``frames`` windows per escape-time workload
    through the pooled engine twice: once with the all-jnp policy and
    once with ``EngineOptions(engine="ask_pooled", policy="tuned")`` --
    the rung that now routes the banded ``region_fill_pooled`` /
    ``region_dwell_pooled`` kernels and the blocked cross-frame
    compaction through the autotune ladder instead of the pre-ISSUE-10
    jnp pin. Bit-identity and zero overflow are hard gate invariants;
    wall times and the tuned-vs-jnp speedup are soft (the tuned tier
    must never lose more than the gate's collapse floor). With
    ``bench_json`` the numbers are written as the machine-readable
    ``BENCH_10.json`` that CI's ``compare_bench`` gate diffs (config
    identical in smoke and full mode, like ``pooled_tier``).
    """
    from repro.workloads import EngineOptions, FrameProblem

    payload = {"version": 1,
               "config": {"n": n, "max_dwell": dwell, "g": 4, "r": 2,
                          "B": 16, "frames": frames},
               "workloads": {}}
    opts_jnp = EngineOptions(engine="ask_pooled", plan=True)
    opts_tuned = EngineOptions(engine="ask_pooled", plan=True,
                               policy="tuned")
    for wl in ("mandelbrot", "julia"):
        prob = FrameProblem(n=n, g=4, r=2, B=16, max_dwell=dwell,
                            backend="jnp", workload=wl)
        case = f"wl={wl} n={n} f={frames}"
        b = np.asarray(prob.bounds, np.float64)
        c = (b[:2] + b[2:]) / 2.0
        w0 = b[2] - b[0]
        bounds = []
        for k in range(frames):
            w = w0 / (1.35 ** k)
            bounds.append((c[0] - w / 2, c[1] - w / 2,
                           c[0] + w / 2, c[1] + w / 2))

        base_canv, base_rep = solve_batch(prob, bounds, options=opts_jnp)
        tuned_canv, rep = solve_batch(prob, bounds, options=opts_tuned)
        t_jnp = _best_time(
            lambda: solve_batch(prob, bounds, options=opts_jnp), reps=2)
        t_tuned = _best_time(
            lambda: solve_batch(prob, bounds, options=opts_tuned), reps=2)
        identical = int(np.array_equal(np.asarray(base_canv),
                                       np.asarray(tuned_canv)))
        speedup = t_jnp / t_tuned if t_tuned > 0 else 0.0
        writer("ask_pooled_tuned_dispatches", case, rep.dispatches)
        writer("ask_pooled_tuned_overflow", case, rep.overflow_dropped)
        writer("ask_pooled_tuned_ring_rows", case, rep.ring_rows)
        writer("ask_pooled_tuned_wall_ms_jnp", case, t_jnp * 1e3)
        writer("ask_pooled_tuned_wall_ms_tuned", case, t_tuned * 1e3)
        writer("ask_pooled_tuned_speedup", case, speedup)
        writer("ask_pooled_tuned_identical", case, identical)
        payload["workloads"][wl] = {
            "identical": identical,
            "overflow": int(rep.overflow_dropped),
            "dispatches": int(rep.dispatches),
            "ring_rows": int(rep.ring_rows),
            "wall_ms_jnp": round(t_jnp * 1e3, 3),
            "wall_ms_tuned": round(t_tuned * 1e3, 3),
            "speedup": round(speedup, 4),
        }
    if bench_json:
        with open(bench_json, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
            fh.write("\n")
    return payload


def tile_service(writer, n=256, dwell=64, chunk=8, bench_json=None):
    """Content-addressed tile cache over the planned front door.

    Replays an overlapping pan/zoom viewport stream twice through
    ``launch.tiles.TileService`` on a feedback ``RenderService``: a
    half-viewport pan across the cardioid, a half-overlap zoom sequence
    one depth down, then a full replay (the interactive steady state --
    most of what a viewer looks at was rendered before). Records the
    cache hit rate, the ``dispatch_planned`` batches actually issued vs
    the uncached baseline (every requested tile re-rendered, coalesced
    the same way), wall times for both, and bit-identity of every
    served tile against a fresh exact ``solve_batch`` render. With
    ``bench_json`` the numbers are written as the machine-readable
    ``BENCH_9.json`` CI's ``compare_bench`` gate diffs (``identical``
    and ``fewer_dispatches`` hard, ``hit_rate`` a hard floor,
    ``dispatches`` a monotone budget, wall times soft; the config is
    the SAME in smoke and full mode so the checked-in baseline's exact
    hit-rate / dispatch budgets stay comparable).
    """
    from repro.launch.frontdoor import FrontDoorStats
    from repro.launch.render_service import RenderService
    from repro.launch.tiles import TileOptions, TileService
    from repro.workloads import FrameProblem

    prob = FrameProblem(n=n, g=4, r=2, B=16, max_dwell=dwell,
                        backend="jnp", workload="mandelbrot")
    svc = RenderService(prob, chunk_frames=chunk, feedback=True)

    # half-overlap pan at one depth + half-overlap zoom one depth down,
    # then the full replay: a deterministic overlapping stream
    pan = [(-1.0 + 0.25 * i, -0.25, -0.5 + 0.25 * i, 0.25)
           for i in range(6)]
    zoom = [(-0.85 + 0.125 * i, -0.125, -0.6 + 0.125 * i, 0.125)
            for i in range(3)]
    views = (pan + zoom) * 2
    case = f"n={n} views={len(views)}"

    def stream(tiles):
        hits = misses = dispatches = retries = 0
        served = {}
        for v in views:
            r = tiles.serve(v)
            hits += r.hits
            misses += r.misses
            dispatches += r.dispatches
            retries += sum(c.retries for c in r.chunks)
            served.update(r.tiles)
        return hits, misses, dispatches, retries, served

    fd = FrontDoorStats()
    cached_tiles = TileService(svc, stats_sink=fd)
    hits, misses, dispatches, retries, served = stream(cached_tiles)
    hit_rate = hits / (hits + misses) if hits + misses else 0.0

    # uncached baseline: a zero-byte cache misses every lookup, so the
    # same stream re-renders every requested tile (same coalescing)
    def uncached():
        return TileService(svc, options=TileOptions(max_bytes=0))

    base_dispatches = stream(uncached())[2]
    t_uncached = _best_time(lambda: stream(uncached()), reps=2)
    t_cached = _best_time(lambda: stream(TileService(svc)), reps=2)
    speedup = t_uncached / t_cached if t_cached > 0 else 0.0

    # bit-identity: every unique tile served (cached or fresh) equals an
    # exact one-shot render of its reconstructed window
    ref = tuple(float(x) for x in prob.bounds)
    addrs = list(served)
    exact, _ = solve_batch(prob, [a.bounds(ref) for a in addrs],
                           p_subdiv=1.0)
    exact = np.asarray(exact)
    identical = int(all(np.array_equal(served[a], exact[j])
                        for j, a in enumerate(addrs)))
    fewer = int(dispatches < base_dispatches)

    writer("ask_tiles_frames_requested", case, hits + misses)
    writer("ask_tiles_tiles_unique", case, len(addrs))
    writer("ask_tiles_hit_rate", case, round(hit_rate, 4))
    writer("ask_tiles_dispatches", case, dispatches)
    writer("ask_tiles_baseline_dispatches", case, base_dispatches)
    writer("ask_tiles_fewer_dispatches", case, fewer)
    writer("ask_tiles_retries", case, retries)
    writer("ask_tiles_cache_bytes", case, cached_tiles.cache.resident_bytes)
    writer("ask_tiles_wall_ms_cached", case, t_cached * 1e3)
    writer("ask_tiles_wall_ms_uncached", case, t_uncached * 1e3)
    writer("ask_tiles_speedup", case, speedup)
    writer("ask_tiles_identical", case, identical)

    assert fd.tile_hits == hits and fd.tile_misses == misses

    payload = {"version": 1,
               "config": {"n": n, "max_dwell": dwell, "g": 4, "r": 2,
                          "B": 16, "chunk": chunk, "views": len(views)},
               "workloads": {"pan_zoom_mandelbrot": {
                   "identical": identical,
                   "hit_rate": round(hit_rate, 4),
                   "dispatches": int(dispatches),
                   "baseline_dispatches": int(base_dispatches),
                   "fewer_dispatches": fewer,
                   "frames_requested": int(hits + misses),
                   "tiles_unique": len(addrs),
                   "cache_bytes": int(cached_tiles.cache.resident_bytes),
                   "wall_ms_cached": round(t_cached * 1e3, 3),
                   "wall_ms_uncached": round(t_uncached * 1e3, 3),
                   "speedup": round(speedup, 4),
               }}}
    if bench_json:
        with open(bench_json, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
            fh.write("\n")
    return payload


def run(writer, full=False, bench_json=None, bench_json_pooled=None,
        bench_json_tiles=None, bench_json_pooled_tuned=None):
    if full:
        engines(writer, n=1024, g=4, r=2, B=32)
        batch_serving(writer, n=512, frames=16)
        sharded_serving(writer, n=256, frames=64, devices=8, chunk=16)
        planner_batch(writer, n=512, dwell=256, n_sparse=12, n_dense=6)
        pipelined_serving(writer, n=256, dwell=128, frames=128, chunk=8)
        feedback_serving(writer, n=256, dwell=128, frames=96, chunk=8)
        workload_serving(writer, n=512, dwell=128, frames=48, chunk=8)
        tuned_tier(writer, n=256, dwell=128, bench_json=bench_json)
        pooled_tier(writer, bench_json=bench_json_pooled)
        tile_service(writer, bench_json=bench_json_tiles)
        pooled_tuned_tier(writer, bench_json=bench_json_pooled_tuned)
    else:  # CI smoke: small n, dp recursion stays cheap
        engines(writer, n=256, g=4, r=2, B=16)
        batch_serving(writer, n=128, frames=4)
        sharded_serving(writer, n=128, frames=16, devices=8, chunk=8)
        planner_batch(writer, n=512, dwell=128, n_sparse=8, n_dense=4)
        pipelined_serving(writer, n=256, dwell=128, frames=64, chunk=8)
        feedback_serving(writer, n=256, dwell=64, frames=48, chunk=4)
        workload_serving(writer, n=256, dwell=64, frames=24, chunk=4)
        tuned_tier(writer, n=256, dwell=64, bench_json=bench_json)
        pooled_tier(writer, bench_json=bench_json_pooled)
        # the tile config is kept identical to full mode (see pooled_tier)
        tile_service(writer, bench_json=bench_json_tiles)
        pooled_tuned_tier(writer, bench_json=bench_json_pooled_tuned)
