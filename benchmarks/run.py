"""Benchmark harness: one suite per paper table/figure.

Prints ``name,case,value`` CSV rows plus a ``suite_<x>,us_per_call,<t>``
summary per suite. Suites:

  cost_model  -> Fig. 3 (Omega) + Fig. 4 (theoretical SBR/MBR speedup)
  mandelbrot  -> Fig. 8 (measured Ex/DP/ASK speedups) + Table 2 analogue
  ask_scan    -> lambda-reduction ladder: ex/dp/ask/ask_fused/ask_scan
                 dispatches, OLT memory, wall time + batched frame serving
  landscape   -> Fig. 7 ({g,r,B} landscape, measured vs model)
  moe         -> beyond-paper: OLT-dispatch MoE
  flops       -> analytic flops/bytes model rows (deterministic; gated
                 against BENCH_FLOPS.json via compare_bench exact_ fields)
  roofline    -> deliverable (g): printed from experiments/dryrun if present

``python -m benchmarks.run [--suite X] [--full] [--json PATH]
[--json-pooled PATH] [--json-tiles PATH] [--json-pooled-tuned PATH]``

``--json PATH`` (ask_scan suite) additionally writes the machine-readable
tuned-tier comparison (``BENCH_6.json`` schema), ``--json-pooled PATH``
the pooled-vs-planned comparison (``BENCH_7.json`` schema),
``--json-tiles PATH`` the tile-cache serving comparison (``BENCH_9.json``
schema), and ``--json-pooled-tuned PATH`` the pooled-engine jnp-vs-tuned
comparison (``BENCH_10.json`` schema); CI's ``benchmarks.compare_bench``
gate diffs each against the checked-in baselines.
"""

from __future__ import annotations

import argparse
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", default="all",
                    choices=("all", "cost_model", "mandelbrot", "ask_scan",
                             "landscape", "moe", "flops", "roofline"))
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the tuned-tier BENCH json (ask_scan suite)")
    ap.add_argument("--json-pooled", default=None, metavar="PATH",
                    help="write the pooled-tier BENCH json (ask_scan suite)")
    ap.add_argument("--json-tiles", default=None, metavar="PATH",
                    help="write the tile-cache BENCH json (ask_scan suite)")
    ap.add_argument("--json-pooled-tuned", default=None, metavar="PATH",
                    help="write the pooled-tuned BENCH json (ask_scan suite)")
    args = ap.parse_args(argv)

    def writer(name, case, value):
        print(f"{name},{case},{value}", flush=True)

    print("name,case,value")
    suites = []
    if args.suite in ("all", "cost_model"):
        from benchmarks import bench_cost_model
        suites.append(("cost_model", lambda: bench_cost_model.run(writer)))
    if args.suite in ("all", "mandelbrot"):
        from benchmarks import bench_mandelbrot
        suites.append(("mandelbrot",
                       lambda: bench_mandelbrot.run(writer, full=args.full)))
    if args.suite in ("all", "ask_scan"):
        from benchmarks import bench_ask_scan
        suites.append(("ask_scan",
                       lambda: bench_ask_scan.run(
                           writer, full=args.full, bench_json=args.json,
                           bench_json_pooled=args.json_pooled,
                           bench_json_tiles=args.json_tiles,
                           bench_json_pooled_tuned=args.json_pooled_tuned)))
    if args.suite in ("all", "landscape"):
        from benchmarks import bench_landscape
        suites.append(("landscape",
                       lambda: bench_landscape.run(writer, full=args.full)))
    if args.suite in ("all", "moe"):
        from benchmarks import bench_moe_dispatch
        suites.append(("moe", lambda: bench_moe_dispatch.run(writer)))
    if args.suite in ("all", "flops"):
        from benchmarks import bench_flops
        suites.append(("flops", lambda: bench_flops.run(writer)))

    for name, fn in suites:
        t0 = time.perf_counter()
        fn()
        print(f"suite_{name},us_per_call,"
              f"{(time.perf_counter() - t0) * 1e6:.0f}", flush=True)

    if args.suite in ("all", "roofline"):
        from pathlib import Path
        if Path("experiments/dryrun").exists() and \
                any(Path("experiments/dryrun").glob("*.json")):
            from benchmarks import roofline
            roofline.main(["--csv", "experiments/roofline.csv",
                           "--json", "experiments/roofline.json"])
        else:
            print("roofline,skipped,no dry-run artifacts "
                  "(run python -m repro.launch.dryrun --all first)")


if __name__ == "__main__":
    main()
