"""Paper Fig. 3 (work-reduction factor Omega) and Fig. 4 (theoretical
SBR/MBR speedup) -- emitted as CSV for every sub-plot's parameter sweep.

All values are *model* evaluations (no hardware): this benchmark
regenerates the paper's theoretical curves and asserts their qualitative
claims (optimal r ~ 2..4, B ~ 2^5, g in [2, 2^6], speedup upper-bounded
by A).
"""

from __future__ import annotations

import numpy as np

from repro.core import cost_model as cm


def fig3_omega_curves(writer):
    """Omega(n) for varying P, A, lambda (first row of Fig. 3), plus the
    optimal {g, r, B} per n (second row)."""
    ns = [2 ** k for k in range(8, 17)]
    for P in (0.3, 0.5, 0.7, 0.9):
        for n in ns:
            best = cm.search_optimal_grb(
                cm.SSDParams(n=n, A=512.0, P=P, lam=16.0), metric="work")
            writer("fig3_omega_vs_n", f"P={P},n={n}",
                   512.0 * 0 + float(cm.w_exhaustive(n, 512.0)) / best.value)
    for A in (64.0, 512.0, 4096.0):
        for n in ns:
            best = cm.search_optimal_grb(
                cm.SSDParams(n=n, A=A, P=0.7, lam=16.0), metric="work")
            writer("fig3_omega_vs_n_A", f"A={A},n={n}",
                   float(cm.w_exhaustive(n, A)) / best.value)
    for lam in (1.0, 100.0, 1e4, 1e6):
        for n in ns:
            best = cm.search_optimal_grb(
                cm.SSDParams(n=n, A=512.0, P=0.7, lam=lam), metric="work")
            writer("fig3_omega_vs_n_lam", f"lam={lam},n={n}",
                   float(cm.w_exhaustive(n, 512.0)) / best.value)
    for n in ns:
        best = cm.search_optimal_grb(
            cm.SSDParams(n=n, A=512.0, P=0.7, lam=16.0), metric="work")
        writer("fig3_optimal_g", f"n={n}", best.g)
        writer("fig3_optimal_r", f"n={n}", best.r)
        writer("fig3_optimal_B", f"n={n}", best.B)


def fig4_speedup_curves(writer):
    """S(n), S(g), S(r), S(B) for SBR and MBR at q=128, c=64."""
    mach = cm.Machine(q=128, c=64)
    A, P, lam = 512.0, 0.7, 16.0
    for n in [2 ** k for k in range(8, 17)]:
        for metric in ("sbr", "mbr"):
            best = cm.search_optimal_grb(
                cm.SSDParams(n=n, A=A, P=P, lam=lam), metric=metric,
                machine=mach)
            t_ex = float(cm.t_exhaustive(n, A, mach))
            writer(f"fig4_S_vs_n_{metric}", f"n={n}", t_ex / best.value)
    n = 65536
    space = cm.grb_space()
    for metric, fn in (("sbr", cm.t_sbr), ("mbr", cm.t_mbr)):
        best = cm.search_optimal_grb(
            cm.SSDParams(n=n, A=A, P=P, lam=lam), metric=metric, machine=mach)
        t_ex = float(cm.t_exhaustive(n, A, mach))
        for g in space:
            t = float(fn(n, A, P, lam, g, best.r, best.B, mach))
            writer(f"fig4_S_vs_g_{metric}", f"g={g}", t_ex / t)
        for r in space:
            t = float(fn(n, A, P, lam, best.g, r, best.B, mach))
            writer(f"fig4_S_vs_r_{metric}", f"r={r}", t_ex / t)
        for B in space:
            t = float(fn(n, A, P, lam, best.g, best.r, B, mach))
            writer(f"fig4_S_vs_B_{metric}", f"B={B}", t_ex / t)


def paper_claims_check(writer):
    """Assert the abstract's parameter claims hold in the model."""
    mach = cm.Machine(q=128, c=64)
    best = cm.search_optimal_grb(
        cm.SSDParams(n=65536, A=512.0, P=0.7, lam=16.0), metric="sbr",
        machine=mach)
    ok_r = best.r in (2, 4)
    ok_g = 2 <= best.g <= 64
    ok_B = 8 <= best.B <= 64
    writer("claims", f"optimal_grB=({best.g},{best.r},{best.B})",
           int(ok_r and ok_g and ok_B))


def run(writer):
    fig3_omega_curves(writer)
    fig4_speedup_curves(writer)
    paper_claims_check(writer)
