"""Analytic FLOP/byte models per (arch x shape) -- the scan-corrected side
of the roofline (DESIGN.md Sec. 7).

XLA's ``cost_analysis()`` counts a ``while`` body once, so for scanned
models its FLOPs/bytes understate per-step work by ~num_groups x. These
closed forms count every matmul in this implementation exactly (same dims,
same remat recompute multipliers) and are cross-checked against
cost_analysis on a single-layer config in tests/test_roofline.py.

Conventions: matmul (m, k) @ (k, n) = 2*m*k*n FLOPs; causal attention
scores/values use the S/2 average live length; training multiplier
accounts for the nested-remat schedule (fwd + outer recompute + inner
recompute + 2x bwd).
"""

from __future__ import annotations

from repro.configs.base import ArchConfig, LayerSpec
from repro.configs.shapes import ShapeCase


def _mixer_flops_per_token(cfg: ArchConfig, spec: LayerSpec, S_ctx: float,
                           kv_tokens: float = None) -> float:
    """Forward FLOPs per *query token* for one mixer, with S_ctx the
    average attended length (S/2 causal train, cache length for decode)."""
    D, hd = cfg.d_model, cfg.head_dim_
    H, Hkv = cfg.num_heads, cfg.num_kv_heads
    f = 0.0
    if spec.mixer in ("attn", "enc", "cross", "attn_cross"):
        n_attn = 2 if spec.mixer == "attn_cross" else 1
        kv_len = kv_tokens if kv_tokens is not None else S_ctx
        for _ in range(n_attn):
            f += 2 * D * H * hd  # q
            f += 2 * 2 * D * Hkv * hd  # k, v
            f += 2 * H * hd * D  # o
            f += 2 * kv_len * H * hd * 2  # scores + values
    elif spec.mixer == "mla":
        m = cfg.mla
        f += 2 * D * H * (m.d_nope + m.d_rope)  # q
        f += 2 * D * m.kv_lora + 2 * D * m.d_rope  # dkv, kr
        f += 2 * m.kv_lora * H * (m.d_nope + m.d_v)  # uk, uv
        f += 2 * (kv_tokens or S_ctx) * H * (m.d_nope + m.d_rope + m.d_v)
        f += 2 * H * m.d_v * D  # o
    elif spec.mixer == "mamba":
        mb = cfg.mamba
        di = mb.expand * D
        dtr = max(1, D // 16)
        f += 2 * D * 2 * di + 2 * di * mb.d_conv
        f += 2 * di * (dtr + 2 * mb.d_state) + 2 * dtr * di
        f += 6 * di * mb.d_state  # recurrence update + readout per step
        f += 2 * di * D
    elif spec.mixer == "mlstm":
        di = cfg.lstm_expand * D
        dh = di // cfg.num_heads
        f += 2 * D * 2 * di + 3 * 2 * di * di + 2 * 2 * di * cfg.num_heads
        if kv_tokens is None:  # parallel (train) form: attention-like
            f += 2 * S_ctx * di * 2
        else:  # recurrent decode: C update + readout
            f += 6 * di * dh
        f += 2 * di * di + 2 * di * D  # o-gate + down
    elif spec.mixer == "slstm":
        f += 4 * 2 * D * D + 2 * D * 2 * D * 2
    return f


def _ffn_flops_per_token(cfg: ArchConfig, spec: LayerSpec) -> float:
    D = cfg.d_model
    if spec.ffn == "mlp":
        mats = 3 if cfg.act == "swiglu" else 2
        return mats * 2 * D * cfg.d_ff
    if spec.ffn == "moe":
        mo = cfg.moe
        mats = 3 if cfg.act == "swiglu" else 2
        # routed experts run on E*C = Sg*K*cf buffer slots per group:
        # capacity padding is real compute (cf multiplies the expert term)
        f = mo.top_k * mo.capacity_factor * mats * 2 * D * mo.d_ff
        f += mats * 2 * D * mo.d_ff * mo.num_shared  # shared
        f += 2 * D * mo.num_experts  # router
        # grouped dispatch + combine einsums: 2 x 2*E*C*D per token with
        # C = cf * Sg * K / E  =>  4 * Sg * K * cf * D
        f += 4 * mo.group_size * mo.top_k * mo.capacity_factor * D
        return f
    return 0.0


def forward_flops(cfg: ArchConfig, case: ShapeCase) -> float:
    """Forward-pass FLOPs for one step (global, all tokens)."""
    B, S = case.global_batch, case.seq_len
    if case.kind == "decode":
        T = B  # one token per sequence
        S_ctx = S  # attends the full cache
        kv = S
    else:
        T = B * S
        S_ctx = S / 2
        kv = None
    per_tok = 0.0
    for spec in cfg.pattern:
        kv_tok = kv if case.kind == "decode" else (
            cfg.num_media_tokens if spec.mixer == "cross" else None)
        per_tok += _mixer_flops_per_token(cfg, spec, S_ctx, kv_tok)
        per_tok += _ffn_flops_per_token(cfg, spec)
    total = per_tok * T * cfg.num_groups
    if cfg.encoder_layers:
        enc_T = B * S if case.kind != "decode" else 0
        enc_per = (_mixer_flops_per_token(cfg, LayerSpec("enc", "mlp"), S / 2)
                   + _ffn_flops_per_token(cfg, LayerSpec("enc", "mlp")))
        total += enc_per * enc_T * cfg.encoder_layers
    total += 2 * cfg.d_model * cfg.padded_vocab * T  # lm head
    return total


def hlo_flops(cfg: ArchConfig, case: ShapeCase) -> float:
    """What the compiled step actually executes, including the nested
    remat recompute (fwd x3 for multi-slot patterns, x2 otherwise) and the
    2x backward."""
    fwd = forward_flops(cfg, case)
    if case.kind != "train":
        return fwd
    # "full": every block's forward runs again for its backward (x2 for
    # single-level remat, x3 nested); "dots" saves matmul outputs so the
    # recompute pass only re-runs the cheap elementwise ops (~0.25 fwd).
    if cfg.remat_policy == "dots":
        recompute = 1.25
    else:
        recompute = 3.0 if len(cfg.pattern) > 1 else 2.0
    return fwd * (recompute + 2.0)


def model_flops(cfg: ArchConfig, case: ShapeCase) -> float:
    """MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE) for train;
    2 N_active per token otherwise (the useful-compute yardstick)."""
    n_active = cfg.active_param_count()
    if case.kind == "train":
        tokens = case.global_batch * case.seq_len
        return 6.0 * n_active * tokens
    if case.kind == "prefill":
        return 2.0 * n_active * case.global_batch * case.seq_len
    return 2.0 * n_active * case.global_batch


def hbm_bytes(cfg: ArchConfig, case: ShapeCase, *, microbatch: int = 1,
              dtype_bytes: int = 2) -> float:
    """Per-step global HBM traffic estimate.

    train: weights re-read per microbatch for fwd + remat + bwd (3-5
    passes), gradient + Adam state read/write (f32), activation
    save/restore for the remat carries.
    decode: weights once + KV cache read + write-back of the new slot.
    """
    N = cfg.param_count()
    B, S = case.global_batch, case.seq_len
    if case.kind == "train":
        passes = (3.0 if len(cfg.pattern) > 1 else 2.0) + 2.0
        w = N * dtype_bytes * passes * microbatch
        opt = N * 4 * 2 * 3 + N * 4  # m/v/master rw + grads
        groups = cfg.num_groups + (cfg.encoder_layers or 0)
        acts = B * S * cfg.d_model * dtype_bytes * groups * 2  # save+load
        return w + opt + acts
    if case.kind == "prefill":
        return N * dtype_bytes + B * S * cfg.d_model * dtype_bytes * (
            cfg.num_groups * 2)
    # decode: weights + cache traffic
    cache = 0.0
    kv_b = 1 if cfg.kv_cache_dtype == "int8" else dtype_bytes
    for spec in cfg.pattern:
        if spec.mixer in ("attn", "attn_cross"):
            cache += 2 * B * S * cfg.num_kv_heads * (
                cfg.head_dim_ * kv_b + (4 if kv_b == 1 else 0))
        elif spec.mixer == "mla":
            cache += B * S * (cfg.mla.kv_lora + cfg.mla.d_rope) * dtype_bytes
        elif spec.mixer == "mamba":
            di = cfg.mamba.expand * cfg.d_model
            cache += B * di * cfg.mamba.d_state * 4
        elif spec.mixer == "mlstm":
            di = cfg.lstm_expand * cfg.d_model
            cache += B * (di // cfg.num_heads) * di * 4
        elif spec.mixer == "slstm":
            cache += B * cfg.d_model * 4 * 3
    cache *= cfg.num_groups
    return N * dtype_bytes + cache
