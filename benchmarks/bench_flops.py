"""Machine-readable baseline for the analytic flops/bytes model.

The seed shipped ``flops_model.py`` / ``roofline.py`` as stdout-only
suites: numbers scrolled past in CI logs and silent model drift was
invisible. This module folds them into the same ``compare_bench``
discipline as the BENCH_N jsons: every (arch x shape) cell of the
closed-form model is emitted as a workload row whose fields all carry
the ``exact_`` prefix -- ``compare_bench`` treats those as HARD
bit-equality invariants, because the model is a pure function of the
checked-in configs. Any drift therefore fails the gate until the
baseline is regenerated deliberately alongside the model change.

Usage::

  python -m benchmarks.bench_flops --json BENCH_FLOPS.json   # regenerate
  python -m benchmarks.compare_bench BENCH_FLOPS.json fresh.json

Flops/bytes are integral-valued analytic counts; they are stored as
exact floats (json round-trips Python floats losslessly), and the
derived ``useful_ratio`` is stored with full precision for the same
reason.
"""

from __future__ import annotations

import argparse
import json

SHAPE_NAMES = ("train_4k", "prefill_32k", "decode_32k")


def build_payload() -> dict:
    from repro.configs.base import get_config, registry
    from repro.configs.shapes import SHAPES
    from benchmarks.flops_model import (forward_flops, hbm_bytes, hlo_flops,
                                        model_flops)

    payload = {"version": 1,
               "config": {"shapes": list(SHAPE_NAMES), "microbatch": 1,
                          "dtype_bytes": 2},
               "workloads": {}}
    for arch in sorted(registry()):
        cfg = get_config(arch)
        for shape in SHAPE_NAMES:
            case = SHAPES[shape]
            fwd = forward_flops(cfg, case)
            hlo = hlo_flops(cfg, case)
            mdl = model_flops(cfg, case)
            payload["workloads"][f"{arch}/{shape}"] = {
                "exact_forward_flops": fwd,
                "exact_hlo_flops": hlo,
                "exact_model_flops": mdl,
                "exact_hbm_bytes": hbm_bytes(cfg, case),
                "exact_useful_ratio": mdl / hlo if hlo else 0.0,
            }
    return payload


def run(writer, bench_json=None) -> dict:
    payload = build_payload()
    for name, row in payload["workloads"].items():
        writer("flops_model_hlo_flops", name, row["exact_hlo_flops"])
        writer("flops_model_useful_ratio", name,
               round(row["exact_useful_ratio"], 4))
    if bench_json:
        with open(bench_json, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
            fh.write("\n")
    return payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Emit the analytic flops-model baseline json")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the BENCH_FLOPS json baseline")
    args = ap.parse_args(argv)

    def writer(name, case, value):
        print(f"{name},{case},{value}", flush=True)

    print("name,case,value")
    run(writer, bench_json=args.json)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
