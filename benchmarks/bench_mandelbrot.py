"""Paper Fig. 8 (measured speedups Ex vs DP vs ASK) + Table 2 analogue.

Wall-times are CPU (jnp backend -- the interpret-mode Pallas path is an
interpreter, not a performance target). A single CPU core is the q=1
regime where the paper's own cost model says subdivision cannot pay
(S(n) plots need q*c parallel resources), so wall-clock speedups here sit
below 1 and ASK's padded buckets can even lose to DP's exact-sized
regions. What this benchmark validates is the *structural* claim --
launch counts (DP one-per-node vs ASK one-per-level, 238x at n=512) and
the work-saved trend with n; the performance claims live in the cost
model (bench_cost_model.py) and the roofline analysis.

Table 2 (best CUDA blocksizes) has no CPU analogue; ``blocksize_table``
reports the structural feasibility of each Pallas block candidate instead:
VMEM footprint and (8, 128) lane alignment on the TPU target.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.ask import _num_levels
from repro.mandelbrot import MandelbrotProblem, solve

DWELL = 128


def _best_time(fn, reps=3):
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts)


def speedup_vs_n(writer, ns=(256, 512, 1024)):
    for n in ns:
        prob = MandelbrotProblem(n=n, g=4, r=2, B=32, max_dwell=DWELL,
                                 backend="jnp")
        # warm the jit caches, then time
        results = {}
        for method in ("ex", "ask", "ask_fused", "dp"):
            if method == "dp" and n > 512:
                continue  # host recursion: CPU-minutes at large n
            solve(prob, method)
            t = _best_time(lambda m=method: solve(prob, m))
            results[method] = t
        t_ex = results["ex"]
        for m, t in results.items():
            if m != "ex":
                writer(f"fig8_speedup_vs_n_{m}", f"n={n}", t_ex / t)
        _, st_ask = solve(prob, "ask")
        writer("fig8_ask_launches", f"n={n}", st_ask.kernel_launches)
        if "dp" in results:
            _, st_dp = solve(prob, "dp")
            writer("fig8_dp_launches", f"n={n}", st_dp.kernel_launches)


def speedup_vs_grb(writer, n=512):
    base = dict(n=n, max_dwell=DWELL, backend="jnp")
    prob0 = MandelbrotProblem(g=4, r=2, B=32, **base)
    solve(prob0, "ex")
    t_ex = _best_time(lambda: solve(prob0, "ex"))
    for g in (2, 4, 8, 16):
        prob = MandelbrotProblem(g=g, r=2, B=32, **base)
        solve(prob, "ask")
        writer("fig8_S_vs_g_ask", f"g={g}",
               t_ex / _best_time(lambda: solve(prob, "ask")))
    for r in (2, 4):
        prob = MandelbrotProblem(g=4, r=r, B=32, **base)
        solve(prob, "ask")
        writer("fig8_S_vs_r_ask", f"r={r}",
               t_ex / _best_time(lambda: solve(prob, "ask")))
    for B in (8, 16, 32, 64):
        prob = MandelbrotProblem(g=4, r=2, B=B, **base)
        solve(prob, "ask")
        writer("fig8_S_vs_B_ask", f"B={B}",
               t_ex / _best_time(lambda: solve(prob, "ask")))


def launch_count_model(writer, n=4096):
    """The structural claim driving the paper's lambda: DP launches one
    kernel per tree node, ASK one per level. Computed exactly from a real
    subdivision run at modest n, then scaled analytically."""
    prob = MandelbrotProblem(n=512, g=4, r=2, B=16, max_dwell=DWELL,
                             backend="jnp")
    _, st_ask = solve(prob, "ask")
    _, st_dp = solve(prob, "dp")
    writer("launches_ask", "n=512", st_ask.kernel_launches)
    writer("launches_dp", "n=512", st_dp.kernel_launches)
    writer("launch_ratio_dp_over_ask", "n=512",
           st_dp.kernel_launches / st_ask.kernel_launches)


def blocksize_table(writer):
    """TPU-target feasibility of Pallas block candidates (Table 2
    analogue): VMEM bytes (int32 out + f32 zr/zi/cr/ci working set) and
    (8,128) alignment."""
    for by, bx in ((8, 8), (16, 16), (32, 32), (64, 4), (64, 8),
                   (128, 128), (256, 256), (512, 512)):
        vmem = by * bx * (4 + 4 * 4)  # out + 4 f32 temporaries
        aligned = (by % 8 == 0) and (bx % 128 == 0)
        fits = vmem * 2 < 16 * 2 ** 20  # double-buffered under ~16 MiB
        writer("table2_block_vmem_bytes", f"{by}x{bx}", vmem)
        writer("table2_block_ok", f"{by}x{bx}", int(aligned and fits))


def run(writer, full=False):
    ns = (256, 512, 1024) if not full else (256, 512, 1024, 2048)
    speedup_vs_n(writer, ns)
    speedup_vs_grb(writer)
    launch_count_model(writer)
    blocksize_table(writer)
