"""Paper Fig. 7: the {g, r, B} configuration landscape, measured.

For each admissible (g, r, B) combination on a fixed n, measure ASK wall
time (jnp backend) and report speedup over Ex -- the measured counterpart
of the cost model's optimum search. Also reports the cost model's
prediction for the same grid so the two landscapes can be compared cell
by cell (the agreement is the paper's central validation).
"""

from __future__ import annotations

import time

from repro.core import cost_model as cm
from repro.mandelbrot import MandelbrotProblem, solve

DWELL = 128


def run(writer, n=512, full=False):
    space = (2, 4, 8, 16, 32) if not full else (2, 4, 8, 16, 32, 64)
    prob0 = MandelbrotProblem(n=n, g=2, r=2, B=32, max_dwell=DWELL,
                              backend="jnp")
    solve(prob0, "ex")
    t0 = time.perf_counter()
    solve(prob0, "ex")
    t_ex = time.perf_counter() - t0

    best = (None, 0.0)
    for g in space:
        for r in space:
            for B in space:
                if not bool(cm.valid_grb(n, g, r, B)):
                    continue
                # the subdivision chain must be realisable in integers
                side = n // g
                ok = side >= B
                while side > B and ok:
                    ok = side % r == 0
                    side //= r
                if not ok or n % g:
                    continue
                prob = MandelbrotProblem(n=n, g=g, r=r, B=B,
                                         max_dwell=DWELL, backend="jnp")
                solve(prob, "ask")  # warm
                t1 = time.perf_counter()
                solve(prob, "ask")
                t = time.perf_counter() - t1
                s = t_ex / t
                writer("fig7_landscape_ask", f"g={g},r={r},B={B}", round(s, 3))
                pred = float(cm.omega(n, DWELL, 0.7, 16.0, g, r, B))
                writer("fig7_landscape_model", f"g={g},r={r},B={B}",
                       round(pred, 3))
                if s > best[1]:
                    best = ((g, r, B), s)
    if best[0]:
        g, r, B = best[0]
        writer("fig7_best_measured", f"g={g},r={r},B={B}", round(best[1], 3))
