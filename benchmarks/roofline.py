"""Roofline report: three terms per (arch x shape x mesh) from the dry-run
artifacts (deliverable g).

  compute    = FLOPs / (chips * 197e12)        [bf16 peak, TPU v5e]
  memory     = bytes / (chips * 819e9)         [HBM bw]
  collective = coll_bytes / (chips * 4 * 50e9) [4 ICI links/chip]

FLOPs/bytes come from the scan-corrected analytic model
(benchmarks/flops_model.py; raw cost_analysis numbers are reported
alongside -- they undercount while-loop bodies, DESIGN.md Sec. 7).
Collective bytes come from the loop-weighted HLO parse stored in each
dry-run JSON. The dominant term is the bottleneck; the fraction
MODEL_FLOPS/HLO_FLOPS is the useful-compute ratio; roofline fraction =
compute_term / max(all terms) (1.0 == compute-bound at peak).

Usage: python -m benchmarks.roofline [--dir experiments/dryrun]
                                     [--tag baseline] [--csv out.csv]
                                     [--json out.json]

``--json`` additionally writes the full analysis rows as a BENCH-schema
json (workload key ``arch/shape/mesh``), so downstream tooling and the
``compare_bench`` gate can diff roofline runs instead of scraping the
printed CSV. The analytic flops-model side -- which needs no dry-run
artifacts -- is gated separately via ``benchmarks.bench_flops`` and the
checked-in ``BENCH_FLOPS.json`` (all-``exact_`` fields, hard equality).
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9 * 4  # B/s / chip, 4 links


def load_records(directory: str, tag: str = None):
    recs = []
    for p in sorted(Path(directory).glob("*.json")):
        r = json.loads(p.read_text())
        if tag and r.get("tag") != tag:
            continue
        recs.append(r)
    return recs


def analyse(rec: dict) -> dict:
    import dataclasses as dc
    from repro.configs import get_config
    from repro.configs.shapes import SHAPES
    from benchmarks.flops_model import hbm_bytes, hlo_flops, model_flops

    cfg = get_config(rec["arch"])
    ov = rec.get("config_overrides", {})
    reps = {k: ov[k] for k in ("q_chunk", "remat", "remat_policy",
                               "kv_cache_dtype") if k in ov}
    if cfg.moe and ("moe_group" in ov or "moe_cf" in ov):
        reps["moe"] = dc.replace(
            cfg.moe,
            group_size=ov.get("moe_group", cfg.moe.group_size),
            capacity_factor=ov.get("moe_cf", cfg.moe.capacity_factor))
    if reps:
        cfg = dc.replace(cfg, **reps)
    case = SHAPES[rec["shape"]]
    chips = 1
    for s in rec["mesh"]["shape"]:
        chips *= s
    mb = rec.get("config_overrides", {}).get("microbatch", 1)

    flops = hlo_flops(cfg, case)
    mflops = model_flops(cfg, case)
    bytes_ = hbm_bytes(cfg, case, microbatch=mb)
    coll = rec["collectives"]["total_bytes"]  # already loop-weighted

    t_c = flops / (chips * PEAK_FLOPS)
    t_m = bytes_ / (chips * HBM_BW)
    t_x = coll / (chips * ICI_BW)
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    # MFU-style score: time the USEFUL flops would take at peak, over the
    # bounding resource's time. 1.0 = useful-compute-bound at peak.
    t_useful = mflops / (chips * PEAK_FLOPS)
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "mesh": "x".join(str(s) for s in rec["mesh"]["shape"]),
        "status": rec["status"],
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dom,
        "roofline_fraction": (t_useful / bound) if bound > 0 else 0.0,
        "model_flops": mflops, "hlo_flops": flops,
        "useful_ratio": mflops / flops if flops else 0.0,
        "raw_cost_flops": rec.get("cost", {}).get("flops", 0.0),
        "peak_gib_per_dev": rec.get("memory", {}).get(
            "peak_per_device_bytes", 0) / 2 ** 30,
        "coll_bytes": coll,
        "unresolved_loops": rec["collectives"].get("unresolved_loops", 0),
        "microbatch": mb,
    }


def what_would_move_it(row: dict) -> str:
    if row["dominant"] == "compute":
        if row["useful_ratio"] < 0.55:
            return "reduce remat recompute (save-dots policy / fewer levels)"
        return "compute-bound at high useful ratio: near roofline"
    if row["dominant"] == "memory":
        return ("cut weight re-reads: larger microbatch / fused opt update; "
                "decode: quantise KV or batch more requests")
    return ("overlap/shrink collectives: async all-gather with compute, "
            "int8 grads on pod axis, or shard differently")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--csv", default="experiments/roofline.csv")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the analysis rows as BENCH-schema json")
    args = ap.parse_args(argv)

    rows = []
    for rec in load_records(args.dir, args.tag):
        if rec["status"] == "ok":
            rows.append(analyse(rec))
        elif rec["status"] == "skipped":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": "x".join(str(s) for s in rec["mesh"]["shape"]),
                         "status": "skipped"})
    hdr = ("arch,shape,mesh,compute_s,memory_s,collective_s,dominant,"
           "roofline_fraction,useful_ratio,peak_gib_per_dev,microbatch,note")
    lines = [hdr]
    for r in rows:
        if r.get("status") == "skipped":
            lines.append(f"{r['arch']},{r['shape']},{r['mesh']},,,,skipped,,,,,")
            continue
        lines.append(
            f"{r['arch']},{r['shape']},{r['mesh']},"
            f"{r['compute_s']:.4e},{r['memory_s']:.4e},{r['collective_s']:.4e},"
            f"{r['dominant']},{r['roofline_fraction']:.3f},"
            f"{r['useful_ratio']:.3f},{r['peak_gib_per_dev']:.2f},"
            f"{r['microbatch']},\"{what_would_move_it(r)}\"")
    out = "\n".join(lines)
    Path(args.csv).parent.mkdir(parents=True, exist_ok=True)
    Path(args.csv).write_text(out + "\n")
    if args.json:
        payload = {"version": 1,
                   "config": {"dir": args.dir, "tag": args.tag},
                   "workloads": {}}
        for r in rows:
            key = f"{r['arch']}/{r['shape']}/{r['mesh']}"
            payload["workloads"][key] = dict(r)
        Path(args.json).parent.mkdir(parents=True, exist_ok=True)
        Path(args.json).write_text(
            json.dumps(payload, indent=2, sort_keys=True) + "\n")
    print(out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
