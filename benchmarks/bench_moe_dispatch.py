"""Beyond-paper benchmark: OLT-compaction MoE dispatch.

Measures (CPU wall time, small dims -- structure not absolute speed):
  * grouped OLT dispatch vs the dense all-experts oracle,
  * token drop rate vs capacity factor (the ASK bucket-overflow analogue).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import moe as M


def run(writer):
    key = jax.random.PRNGKey(0)
    E, K, D, F = 16, 2, 128, 256
    p = M.moe_init(key, d_model=D, d_ff=F, num_experts=E, top_k=K)
    x = jax.random.normal(key, (8, 512, D))

    disp = jax.jit(lambda x: M.moe_apply(
        p, x, num_experts=E, top_k=K, capacity_factor=1.25,
        group_size=512)[0])
    dense = jax.jit(lambda x: M.moe_apply_dense_fallback(
        p, x, num_experts=E, top_k=K))
    for name, fn in (("olt_dispatch", disp), ("dense_all_experts", dense)):
        jax.block_until_ready(fn(x))
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(fn(x))
        writer(f"moe_{name}_us", f"E={E},K={K}",
               (time.perf_counter() - t0) / 3 * 1e6)

    # drop rate vs capacity factor (counts > capacity are dropped)
    for cf in (0.5, 1.0, 1.25, 2.0):
        _, aux = M.moe_apply(p, x, num_experts=E, top_k=K,
                             capacity_factor=cf, group_size=512)
        T = x.shape[0] * x.shape[1]
        Sg = 512
        C = max(1, int(cf * Sg * K / E))
        counts = np.asarray(aux["expert_counts"], np.float64)
        # overflow per expert per group is bounded below by total-G*C
        groups = T // Sg
        dropped = float(np.maximum(counts - groups * C, 0).sum())
        writer("moe_drop_rate", f"cf={cf}", round(dropped / (T * K), 4))
